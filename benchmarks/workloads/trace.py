"""Replayable request traces for the serving benchmark harness.

A :class:`Trace` is the unit of reproducibility between the workload
*generator* (``generator.py``) and the engine *replayer* (``runner.py``):
a seeded generator run produces a trace, the trace serializes to canonical
JSON whose bytes are a pure function of (spec, seed), and the runner replays
it against a :class:`~repro.serving.ServingEngine` in virtual time.  The
SHA-256 ``fingerprint`` of the canonical bytes is stamped into every
``BENCH_e2e.json`` report, so a perf number can always be traced back to the
exact request sequence that produced it — and the regression comparator
(``benchmarks/compare.py``) refuses to diff runs whose traces differ.

Arrival times are in **virtual time units**; the replayer maps one engine
step to ``step_dt`` units (default 1.0), so "rate" in the generator specs
reads as *requests per engine step*.  This keeps replay fully deterministic
— wall-clock only enters through the measured per-request latencies, never
through the scheduling structure.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field

TRACE_VERSION = 1


@dataclass
class TraceRequest:
    """One request of a workload trace (JSON-serializable)."""
    uid: int
    arrival: float                 # virtual-time units (engine steps)
    prompt: list                   # token IDs (list[int], canonical form)
    max_new_tokens: int
    temperature: float = 0.0
    # Per-request service-level objectives (wall-clock seconds); None = no SLO
    # on that axis.  A request is *good* iff every set SLO is met.
    slo_ttft_s: float | None = None
    slo_tpot_s: float | None = None
    # Shared-prefix bookkeeping: requests with the same non-negative group id
    # share their leading ``prefix_len`` prompt tokens.
    prefix_group: int = -1
    prefix_len: int = 0

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRequest":
        return cls(**d)


@dataclass
class Trace:
    """A seeded, replayable request sequence plus its provenance."""
    name: str
    seed: int
    spec: dict                     # the generating WorkloadSpec, as a dict
    requests: list = field(default_factory=list)   # list[TraceRequest]
    version: int = TRACE_VERSION

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "name": self.name,
            "seed": self.seed,
            "spec": self.spec,
            "requests": [r.to_dict() for r in self.requests],
        }

    def to_json(self) -> str:
        """Canonical JSON: sorted keys, no whitespace variance — byte-stable
        for a given (spec, seed), which is what the same-seed property test
        and the fingerprint rely on."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_dict(cls, d: dict) -> "Trace":
        if d.get("version") != TRACE_VERSION:
            raise ValueError(
                f"trace version {d.get('version')!r} != {TRACE_VERSION} "
                "(regenerate the trace with this tree's generator)")
        return cls(name=d["name"], seed=d["seed"], spec=d["spec"],
                   requests=[TraceRequest.from_dict(r) for r in d["requests"]],
                   version=d["version"])

    @classmethod
    def from_json(cls, s: str) -> "Trace":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- identity ------------------------------------------------------------

    def fingerprint(self) -> str:
        """SHA-256 of the canonical JSON bytes (prefixed for greppability)."""
        h = hashlib.sha256(self.to_json().encode("utf-8")).hexdigest()
        return f"sha256:{h}"

    # -- derived -------------------------------------------------------------

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def total_prompt_tokens(self) -> int:
        return sum(len(r.prompt) for r in self.requests)

    def total_output_tokens(self) -> int:
        return sum(r.max_new_tokens for r in self.requests)
