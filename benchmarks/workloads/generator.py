"""Seeded workload generation: arrival processes x length distributions x
shared-prefix mixes, plus the adversarial presets the serving stack needs to
be benchmarked against (preemption storms, eviction pressure, decode-heavy
tails).

The design follows the request-generator layer of Sarathi-class serving
benchmarks: a :class:`WorkloadSpec` is a declarative description (pure data,
JSON-round-trippable) and :func:`generate` is a pure function
``(spec) -> Trace`` — same spec, same seed, byte-identical trace (pinned by
``tests/test_workloads.py``).

Arrival processes (``spec.arrival["kind"]``), rates in requests per engine
step (see ``trace.py`` on virtual time):

* ``uniform`` — evenly spaced arrivals at ``1/rate``;
* ``poisson`` — i.i.d. exponential inter-arrivals (memoryless open-loop
  traffic, the standard serving-benchmark model);
* ``gamma``  — gamma inter-arrivals with shape ``cv`` (coefficient-of-
  variation knob: shape < 1 is burstier than Poisson, > 1 smoother);
* ``burst``  — everything arrives at t=0 (closed-loop batch; the preemption
  storm uses this to slam admission).

Length distributions (``prompt_len`` / ``output_len``):

* ``fixed``     — constant ``value``;
* ``uniform``   — integer uniform on [lo, hi];
* ``lognormal`` — heavy-tailed lengths (``mean``/``sigma`` of the underlying
  normal), clipped to [lo, hi] — the shape real prompt-length histograms
  take;
* ``choice``    — categorical over ``values`` with optional ``weights``.

Shared-prefix mixes (``shared_prefix``): ``fraction`` of requests are
assigned round-robin to one of ``groups`` prefix groups; each group shares
its leading ``prefix_len`` prompt tokens (a system prompt / few-shot
template), the rest of the prompt is a fresh tail.  Group membership and the
shared length are recorded on each :class:`~benchmarks.workloads.trace.
TraceRequest` so tests can assert the declared structure.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

import numpy as np

from benchmarks.workloads.trace import Trace, TraceRequest

DEFAULT_VOCAB = 256   # matches the reduced() config zoo vocab floor


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one benchmark workload (pure data)."""
    name: str
    n_requests: int
    arrival: dict
    prompt_len: dict
    output_len: dict
    shared_prefix: dict | None = None
    slo: dict = field(default_factory=dict)     # {"ttft_s":, "tpot_s":}
    temperature: float = 0.0
    vocab: int = DEFAULT_VOCAB
    seed: int = 0
    # Engine-construction hints the runner applies (slots, prefill_chunk,
    # block_size, kv_blocks, max_len, prefix_cache).  Part of the spec so an
    # adversarial trace (tight pool, tiny cache capacity) is reproducible
    # from the trace file alone.
    engine: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def _arrivals(spec_a: dict, n: int, rng: np.random.Generator) -> np.ndarray:
    kind = spec_a.get("kind", "uniform")
    rate = float(spec_a.get("rate", 1.0))
    if kind == "burst":
        return np.zeros(n)
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    if kind == "uniform":
        gaps = np.full(n, 1.0 / rate)
    elif kind == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n)
    elif kind == "gamma":
        cv = float(spec_a.get("cv", 0.25))       # shape; < 1 = bursty
        if cv <= 0:
            raise ValueError(f"gamma cv must be > 0, got {cv}")
        gaps = rng.gamma(shape=cv, scale=1.0 / (rate * cv), size=n)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")
    t = np.cumsum(gaps)
    return t - t[0]                              # first request arrives at 0


def _lengths(spec_l: dict, n: int, rng: np.random.Generator) -> np.ndarray:
    kind = spec_l.get("kind", "fixed")
    if kind == "fixed":
        out = np.full(n, int(spec_l["value"]))
    elif kind == "uniform":
        out = rng.integers(int(spec_l["lo"]), int(spec_l["hi"]) + 1, size=n)
    elif kind == "lognormal":
        raw = rng.lognormal(float(spec_l["mean"]), float(spec_l["sigma"]),
                            size=n)
        out = np.clip(np.round(raw), int(spec_l.get("lo", 1)),
                      int(spec_l["hi"])).astype(np.int64)
    elif kind == "choice":
        vals = np.asarray(spec_l["values"], np.int64)
        w = spec_l.get("weights")
        p = None if w is None else np.asarray(w, float) / np.sum(w)
        out = rng.choice(vals, size=n, p=p)
    else:
        raise ValueError(f"unknown length kind {kind!r}")
    if (out < 1).any():
        raise ValueError(f"{kind} length spec produced a length < 1")
    return out.astype(np.int64)


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def generate(spec: WorkloadSpec) -> Trace:
    """Materialize ``spec`` into a replayable :class:`Trace` (pure, seeded)."""
    n = spec.n_requests
    rng = np.random.default_rng(spec.seed)
    arrivals = _arrivals(spec.arrival, n, rng)
    plens = _lengths(spec.prompt_len, n, rng)
    olens = _lengths(spec.output_len, n, rng)

    # Shared-prefix structure: group prefixes drawn first (so membership
    # changes don't perturb unrelated requests' tokens less than necessary).
    sp = spec.shared_prefix or {}
    groups = int(sp.get("groups", 0))
    prefix_len = int(sp.get("prefix_len", 0))
    fraction = float(sp.get("fraction", 1.0))
    prefixes = [rng.integers(0, spec.vocab, size=prefix_len).tolist()
                for _ in range(groups)]

    slo_ttft = spec.slo.get("ttft_s")
    slo_tpot = spec.slo.get("tpot_s")

    reqs = []
    shared_member = 0
    for i in range(n):
        plen = int(plens[i])
        group = -1
        if groups and prefix_len and rng.random() < fraction:
            group = shared_member % groups
            shared_member += 1
        if group >= 0:
            # At least one fresh tail token: the engine always recomputes the
            # final prompt token, and identical full prompts would measure
            # dedup, not prefix reuse.
            tail = max(1, plen - prefix_len)
            prompt = prefixes[group] + rng.integers(
                0, spec.vocab, size=tail).tolist()
            plen_eff = prefix_len
        else:
            prompt = rng.integers(0, spec.vocab, size=plen).tolist()
            plen_eff = 0
        reqs.append(TraceRequest(
            uid=i, arrival=float(arrivals[i]), prompt=prompt,
            max_new_tokens=int(olens[i]), temperature=spec.temperature,
            slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot,
            prefix_group=group, prefix_len=plen_eff if group >= 0 else 0))
    return Trace(name=spec.name, seed=spec.seed, spec=spec.to_dict(),
                 requests=reqs)


# ---------------------------------------------------------------------------
# named presets (the workload taxonomy — see docs/benchmarking.md)
# ---------------------------------------------------------------------------

def _scale(n: int, quick: bool) -> int:
    return max(2, n // 2) if quick else n


def preset(name: str, *, quick: bool = False, seed: int = 0) -> WorkloadSpec:
    """Named workload presets.  ``quick`` halves request counts (CI smoke);
    ``seed`` shifts every stream (trace identity is (name, quick, seed))."""
    mk = WorkloadSpec
    if name == "steady":
        # Open-loop Poisson arrivals, mixed prompt lengths: the baseline
        # "realistic traffic" scenario and the headline percentile numbers.
        return mk(
            name=name, n_requests=_scale(12, quick),
            arrival={"kind": "poisson", "rate": 0.5},
            prompt_len={"kind": "lognormal", "mean": 3.0, "sigma": 0.6,
                        "lo": 4, "hi": 96},
            output_len={"kind": "uniform", "lo": 4, "hi": 12},
            slo={"ttft_s": 2.0, "tpot_s": 0.5},
            seed=seed,
            engine={"slots": 4, "prefill_chunk": 16, "max_len": 128})
    if name == "bursty":
        # Gamma arrivals with cv << 1: clumped admissions stress the
        # one-prefill-per-step policy's TTFT tail.
        return mk(
            name=name, n_requests=_scale(12, quick),
            arrival={"kind": "gamma", "rate": 0.8, "cv": 0.15},
            prompt_len={"kind": "uniform", "lo": 8, "hi": 64},
            output_len={"kind": "uniform", "lo": 4, "hi": 10},
            slo={"ttft_s": 3.0, "tpot_s": 0.5},
            seed=seed,
            engine={"slots": 4, "prefill_chunk": 16, "max_len": 128})
    if name == "shared-prefix":
        # System-prompt sharing: ~75%-shared prompts over a few templates;
        # run with the prefix cache ON (the runner replays it cache-off too,
        # asserting token identity — the serving-regression contract).
        return mk(
            name=name, n_requests=_scale(8, quick),
            arrival={"kind": "uniform", "rate": 1.0},
            prompt_len={"kind": "fixed", "value": 64},
            output_len={"kind": "fixed", "value": 8},
            shared_prefix={"groups": 2, "prefix_len": 48, "fraction": 1.0},
            slo={"ttft_s": 2.0, "tpot_s": 0.5},
            seed=seed,
            engine={"slots": 2, "prefill_chunk": 16, "max_len": 128,
                    "prefix_cache": True})
    if name == "decode-heavy":
        # Short prompts, long outputs: steady-state decode cadence (TPOT)
        # dominates; the GEMV regime the T-SAR dataflow optimizes.
        return mk(
            name=name, n_requests=_scale(8, quick),
            arrival={"kind": "poisson", "rate": 1.0},
            prompt_len={"kind": "uniform", "lo": 3, "hi": 10},
            output_len={"kind": "fixed", "value": 12 if quick else 24},
            slo={"ttft_s": 1.0, "tpot_s": 0.5},
            seed=seed,
            engine={"slots": 4, "prefill_chunk": 8, "max_len": 96})
    if name == "preemption-storm":
        # Adversarial: a burst of long prompts into a deliberately tight
        # block pool — recompute-preemption must fire (the runner asserts
        # it) and every request must still complete.
        return mk(
            name=name, n_requests=_scale(6, quick),
            arrival={"kind": "burst"},
            prompt_len={"kind": "uniform", "lo": 24, "hi": 40},
            output_len={"kind": "fixed", "value": 8},
            slo={"ttft_s": 5.0, "tpot_s": 1.0},
            seed=seed,
            engine={"slots": 2, "prefill_chunk": 8, "max_len": 64,
                    "block_size": 4, "kv_blocks": 16,
                    "prefix_cache": True})
    if name == "eviction-pressure":
        # Adversarial: many distinct prefixes through a capacity-capped
        # prefix cache — LRU eviction must fire without stranding
        # admissions (runner asserts evictions > 0).
        return mk(
            name=name, n_requests=_scale(8, quick),
            arrival={"kind": "uniform", "rate": 1.0},
            prompt_len={"kind": "fixed", "value": 24},
            output_len={"kind": "fixed", "value": 4},
            shared_prefix={"groups": 6, "prefix_len": 16, "fraction": 1.0},
            slo={"ttft_s": 10.0, "tpot_s": 2.0},
            seed=seed,
            engine={"slots": 2, "prefill_chunk": 8, "max_len": 64,
                    "block_size": 4, "prefix_cache": 4})
    if name == "mixed":
        # The historical bench_e2e request list, as a trace: mixed prompt
        # lengths, everything queued up front (closed-loop), chunked-vs-
        # whole comparable.
        return mk(
            name=name, n_requests=_scale(8, quick),
            arrival={"kind": "burst"},
            prompt_len={"kind": "choice",
                        "values": [5, 9, 48, 12, 96, 7, 24, 64]},
            output_len={"kind": "fixed", "value": 8 if quick else 16},
            slo={"ttft_s": 5.0, "tpot_s": 1.0},
            seed=seed,
            engine={"slots": 4, "prefill_chunk": 16, "max_len": 256})
    raise ValueError(
        f"unknown workload preset {name!r}; available: {sorted(WORKLOADS)}")


# Preset registry: name -> short description (the taxonomy table in
# docs/benchmarking.md mirrors this).
WORKLOADS = {
    "steady": "Poisson arrivals, lognormal prompts — headline percentiles",
    "bursty": "gamma (cv=0.15) clumped arrivals — TTFT tail stress",
    "shared-prefix": "75%-shared system prompts — prefix-cache reuse",
    "decode-heavy": "short prompts, long outputs — TPOT/decode cadence",
    "preemption-storm": "burst of long prompts, tight KV pool — preemptions",
    "eviction-pressure": "distinct prefixes, capacity-capped cache — LRU",
    "mixed": "legacy mixed-length closed-loop list (chunked-vs-whole)",
}
