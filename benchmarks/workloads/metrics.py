"""Percentile latency metrics, goodput under per-request SLOs, and scheduler
counters for trace-driven serving benchmark runs.

Means hide exactly the behavior a serving stack is judged on — the tail.
Every latency here is therefore reported as {p50, p90, p99, mean, max}
(nearest-rank-interpolated percentiles over finished requests), and goodput
is the fraction (and rate) of requests that met *their own* SLOs, not an
aggregate average:

* **TTFT** — time to first token (queueing + prefill), ``Request.ttft``;
* **TPOT** — mean time per output token after the first, ``Request.tpot``;
* **queue** — submit -> first admission into a slot, ``Request.queue_s``;
* **good request** — every SLO the trace set for it is met
  (``ttft <= slo_ttft_s * slo_scale`` and ``tpot <= slo_tpot_s * slo_scale``;
  an unset axis always passes; a request that produced no tokens is never
  good).

``slo_scale`` is the per-machine calibration factor: preset SLO thresholds
were tuned against a reference decode-step latency of
:data:`NOMINAL_DECODE_STEP_S`, and the suite driver measures the actual
decode-step latency at start (``runner.measure_slo_scale``) and scales every
threshold by ``measured / nominal`` — so goodput compares serving *behavior*
across machines instead of comparing their raw CPUs.  The factor is recorded
in the report provenance (``slo_scale`` / ``ref_decode_step_s`` top-level
keys).

Counters are the deterministic side of a run: given the same trace and
code, preemptions, scheduled prefill tokens, cache hit rates and step counts
are machine-independent, which is what lets ``benchmarks/compare.py`` gate
them exactly while wall-clock metrics get tolerances.  Since the engine's
telemetry moved into the typed registry (``repro.obs.metrics``), the counter
block also carries the registry's step-accounting counters (planned vs
realized step tokens, prefill/decode step split, admissions) — all
exact-match class.
"""
from __future__ import annotations

import numpy as np

PERCENTILES = (50, 90, 99)

# Reference decode-step latency the preset SLO thresholds assume (seconds
# per pure-decode engine step of the calibration engine — reduced
# bitnet-2b-4t, 2 slots — measured on the machine the thresholds were
# tuned on; dominated by per-step jit dispatch at this model scale).
# ``measure_slo_scale`` divides a fresh measurement by this to get the
# run's ``slo_scale``.
NOMINAL_DECODE_STEP_S = 0.12


def percentile_summary(values) -> dict:
    """{p50, p90, p99, mean, max, n} over ``values`` (NaNs for empty)."""
    xs = np.asarray([v for v in values if v is not None], float)
    if xs.size == 0:
        return {**{f"p{p}": float("nan") for p in PERCENTILES},
                "mean": float("nan"), "max": float("nan"), "n": 0}
    out = {f"p{p}": float(np.percentile(xs, p)) for p in PERCENTILES}
    out["mean"] = float(xs.mean())
    out["max"] = float(xs.max())
    out["n"] = int(xs.size)
    return out


def is_good(req, tr, slo_scale: float = 1.0) -> bool:
    """Did engine-request ``req`` meet trace-request ``tr``'s SLOs, with
    thresholds scaled by the machine calibration factor?"""
    if not req.out_tokens:
        return False
    if tr.slo_ttft_s is not None:
        if req.ttft is None or req.ttft > tr.slo_ttft_s * slo_scale:
            return False
    if tr.slo_tpot_s is not None and req.tpot is not None:
        if req.tpot > tr.slo_tpot_s * slo_scale:
            return False
    return True


def goodput(requests, trace, wall_s: float, slo_scale: float = 1.0) -> dict:
    """Requests meeting their SLOs: fraction, count, and rate per wall
    second.  ``requests`` are engine Requests ordered like
    ``trace.requests`` (the replayer guarantees uid alignment)."""
    by_uid = {tr.uid: tr for tr in trace.requests}
    good = sum(1 for r in requests if is_good(r, by_uid[r.uid], slo_scale))
    total = len(requests)
    return {
        "slo_attained": good / total if total else float("nan"),
        "good": int(good),
        "total": int(total),
        "good_per_s": good / wall_s if wall_s > 0 else float("nan"),
    }


def latency_metrics(requests, trace, wall_s: float,
                    slo_scale: float = 1.0) -> dict:
    """The full per-workload metrics block of a BENCH_e2e report."""
    done = [r for r in requests if r.out_tokens]
    total_out = sum(len(r.out_tokens) for r in done)
    return {
        "ttft_s": percentile_summary(r.ttft for r in done),
        "tpot_s": percentile_summary(r.tpot for r in done),
        "queue_s": percentile_summary(r.queue_s for r in done),
        "goodput": goodput(requests, trace, wall_s, slo_scale),
        "output_tok_s": total_out / wall_s if wall_s > 0 else float("nan"),
        "wall_s": float(wall_s),
    }


def engine_counters(engine) -> dict:
    """Deterministic scheduler/engine counters for the report (exact-gated
    by the comparator — see module docstring)."""
    s = engine.stats
    out = {
        "steps": int(s["steps"]),
        "preemptions": int(s["preemptions"]),
        "preempt_readmissions": int(engine.sched.readmissions),
        "prefill_tokens": int(s["prefill_tokens"]),
        "prefill_tokens_planned": int(engine.sched.prefill_tokens_planned),
        "cached_tokens_skipped": int(engine.sched.cached_tokens_skipped),
        "decode_tokens": int(s["decode_tokens"]),
        "total_tokens": int(s["total_tokens"]),
        "max_step_tokens": int(s["max_step_tokens"]),
        "peak_kv_blocks": int(s["peak_kv_blocks"]),
        "whole_prefills": int(s["whole_prefills"]),
    }
    # Registry-only step accounting (no legacy stats key): planned is the
    # static step width the jitted call multiplies (flat: T; rectangular:
    # the padded B*C), so realized/planned is the padding-waste signal the
    # flat token layout moved.  ``rejections`` keeps goodput denominators
    # honest: prompt-too-long requests are finished-ignored at admission
    # and would otherwise be metric-invisible.
    reg = engine.metrics
    for k in ("planned_tokens", "realized_tokens", "prefill_steps",
              "decode_steps", "admissions", "rejections"):
        out[k] = int(reg.get(k).value)
    if "prefix_hit_rate" in s:
        out["prefix_hit_rate"] = round(float(s["prefix_hit_rate"]), 6)
        out["prefix_hit_tokens"] = int(s["prefix_hit_tokens"])
        out["prefix_evictions"] = int(s["prefix_evictions"])
        out["cached_blocks"] = int(s["cached_blocks"])
    # The decode-bucket kernel the compiled plan committed to (CI asserts
    # this column exists so the plan path can't fall out of the benchmark).
    out["plan_kernel"] = (engine.plan.dominant_kernel(engine.slots)
                          if engine.plan is not None else "none")
    return out
