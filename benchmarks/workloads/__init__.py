"""Trace-driven workload harness for the serving benchmarks.

Layers (each its own module, composable from tests and drivers):

* ``trace``     — :class:`Trace` / :class:`TraceRequest`: the seeded,
  replayable, canonically-serialized request sequence (+ fingerprint);
* ``generator`` — :class:`WorkloadSpec` + :func:`generate`: arrival
  processes, length distributions, shared-prefix mixes, and the named
  preset taxonomy (including adversarial traces);
* ``metrics``   — percentile TTFT/TPOT/queue, goodput under per-request
  SLOs, deterministic engine counters;
* ``runner``    — virtual-time replay against ``ServingEngine`` and the
  ``run_suite`` driver that assembles ``BENCH_e2e.json``;
* ``schema``    — the versioned report schema, validator, canonical IO.

See ``docs/benchmarking.md`` for the taxonomy and the regression-gating
workflow (``benchmarks/compare.py``).
"""
from benchmarks.workloads.generator import (  # noqa: F401
    WORKLOADS,
    WorkloadSpec,
    generate,
    preset,
)
from benchmarks.workloads.runner import (  # noqa: F401
    build_engine,
    replay,
    run_suite,
    run_workload,
)
from benchmarks.workloads.trace import Trace, TraceRequest  # noqa: F401
