"""Pallas kernel timings (interpret mode — correctness-path cost only; real
TPU timings come from the roofline analysis, not this container).

Sweeps block-kill probability ``p_zero`` so the sparse-vs-dense crossover is
visible in the CSV: each row carries the measured weight density, live-block
density, and the kernel ``select_kernel`` would dispatch at that density.
The ``tsar_sparse`` interpret-mode time drops with block density (its grid
runs over live blocks only); the dense kernels' stays flat.

**Calibration mode** (``python -m benchmarks.bench_kernels --calibrate``):
the sparse cost model's issue tax started as an analytic 1.1x guess; this
mode measures dense-vs-sparse timings over the density sweep, fits the tax
(:func:`fit_issue_tax` — the median of ``t_sparse / (block_density *
t_dense)``, i.e. the per-live-block slowdown relative to the dense kernel's
per-block time), installs it in ``repro.core.hw`` via ``set_calibration``,
and optionally persists it (``--save FILE`` -> ``hw.load_calibration`` at
deployment).  Every registry cost model reads the live value through
``hw.sparse_issue_tax()``, so the fitted constant shifts the analytic
break-even machine-wide.
"""
from __future__ import annotations

import statistics

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.core import dataflow, hw, ternary
from repro.kernels import ops
from repro.sparse import format as sparse_format, stats as sparse_stats

P_ZERO_SWEEP = (0.1, 1.0 / 3.0, 0.6, 0.9)
BK = BM = 128   # sparse block tiling for the sweep (small shapes)


def run(quick: bool = False):
    rows = []
    shapes = [(8, 512, 512)] if quick else [(8, 512, 512), (1, 1024, 1024)]
    for (n, k, m) in shapes:
        key = jax.random.PRNGKey(n + k)
        x = jax.random.normal(key, (n, k))
        scale = jnp.ones((m,))
        for p_zero in P_ZERO_SWEEP:
            # Block-structured sparsity: p_zero kills whole (BK, BM) blocks
            # (unstructured zeros never kill a full block — see
            # sparse/format.random_block_sparse_ternary).
            t = sparse_format.random_block_sparse_ternary(
                key, (k, m), bk=BK, bm=BM, p_zero_block=p_zero)
            bst = sparse_format.from_ternary(t, scale, bk=BK, bm=BM)
            density = sparse_stats.weight_density(t)
            choice = dataflow.select_kernel(
                n, k, m, density=density, block_density=bst.block_density,
                block_shape=(BK, BM))
            derived = (f"interpret_mode=1;p_zero_block={p_zero:.2f};"
                       f"density={density:.3f};block_density={bst.block_density:.3f};"
                       f"kernel_choice={choice.kernel}")

            tw = ternary.pack(t.astype(jnp.float32), scale)
            tt = timeit(lambda x: ops.tsar_matmul(x, tw, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_mxu_{n}x{k}x{m}_pz{p_zero:.2f}", tt * 1e6, derived)
            ts = timeit(lambda x: ops.tsar_sparse_matmul(x, bst, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_sparse_{n}x{k}x{m}_pz{p_zero:.2f}", ts * 1e6, derived)
            rows.append((n, k, m, p_zero, bst.block_density, ts))

        # Dense-path AP/OP + LUT baselines at the BitNet prior (unswept).
        t = ternary.random_ternary(key, (k, m))
        tw = ternary.pack(t.astype(jnp.float32), scale)
        for df in ("AP", "OP"):
            tt = timeit(lambda x: ops.tsar_matmul(x, tw, dataflow=df, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_mxu_{df}_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
        ip, iz = ternary.pack_indices(t, 4)
        tt = timeit(lambda x: ops.tsar_lut_gemv(x, ip, iz, scale, c=4, interpret=True),
                    x, reps=2, warmup=1)
        csv_row(f"pallas_lut_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
    return rows


# ---------------------------------------------------------------------------
# Issue-tax calibration
# ---------------------------------------------------------------------------

def fit_issue_tax(samples) -> float:
    """Fit the sparse issue tax from measured (block_density, t_sparse_s,
    t_dense_s) rows.

    Model: the sparse kernel performs ``block_density`` of the dense
    kernel's block work, times an issue-efficiency tax — so
    ``tax = t_sparse / (block_density * t_dense)`` per row; the median over
    the sweep rejects timing outliers.  Pure function: unit-testable without
    touching a clock.
    """
    ratios = [ts / (bd * td) for bd, ts, td in samples
              if bd > 0.0 and td > 0.0 and ts > 0.0]
    if not ratios:
        raise ValueError("no usable (block_density, t_sparse, t_dense) rows")
    return float(statistics.median(ratios))


def measure_issue_tax_samples(quick: bool = True, reps: int = 3):
    """Timed dense-vs-sparse pairs over the block-kill sweep (interpret
    mode — relative per-block cost is what the fit needs, not absolute TPU
    time)."""
    shapes = [(8, 512, 512)] if quick else [(8, 512, 512), (1, 1024, 1024)]
    samples = []
    for (n, k, m) in shapes:
        key = jax.random.PRNGKey(n + k)
        x = jax.random.normal(key, (n, k))
        scale = jnp.ones((m,))
        t_dense_ref = None
        for p_zero in P_ZERO_SWEEP:
            t = sparse_format.random_block_sparse_ternary(
                key, (k, m), bk=BK, bm=BM, p_zero_block=p_zero)
            bst = sparse_format.from_ternary(t, scale, bk=BK, bm=BM)
            if bst.n_live == 0:
                continue
            if t_dense_ref is None:
                tw = ternary.pack(t.astype(jnp.float32), scale)
                t_dense_ref = timeit(
                    lambda x: ops.tsar_matmul(x, tw, interpret=True),
                    x, reps=reps, warmup=1)
            ts = timeit(lambda x: ops.tsar_sparse_matmul(x, bst, interpret=True),
                        x, reps=reps, warmup=1)
            samples.append((bst.block_density, ts, t_dense_ref))
    return samples


def calibrate(quick: bool = True, save: str | None = None,
              apply: bool = True) -> float:
    """Measure, fit, and install the sparse issue tax (see module docstring).

    Returns the fitted tax.  ``apply=False`` fits without mutating the
    process-global calibration (dry run); ``save`` writes the calibration
    JSON that ``repro.core.hw.load_calibration`` consumes at deployment —
    independently of ``apply``, so fit-and-persist needs no global install.
    """
    tax = fit_issue_tax(measure_issue_tax_samples(quick=quick))
    csv_row("sparse_issue_tax_fit", tax,   # dimensionless, not us
            f"analytic_default={hw.SPARSE_ISSUE_TAX};applied={int(apply)}")
    if apply:
        hw.set_calibration(sparse_issue_tax=tax)
    if save:
        hw.save_calibration(save, {"sparse_issue_tax": tax})
    return tax


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the sparse issue tax from measured timings")
    ap.add_argument("--save", default=None,
                    help="write the fitted calibration JSON here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.calibrate:
        tax = calibrate(quick=args.quick, save=args.save)
        print(f"# fitted sparse_issue_tax = {tax:.3f} "
              f"(analytic default {hw.SPARSE_ISSUE_TAX})")
    else:
        run(quick=args.quick)
