"""Pallas kernel timings (interpret mode — correctness-path cost only; real
TPU timings come from the roofline analysis, not this container).

Sweeps block-kill probability ``p_zero`` so the sparse-vs-dense crossover is
visible in the CSV: each row carries the measured weight density, live-block
density, and the kernel ``select_kernel`` would dispatch at that density.
The ``tsar_sparse`` interpret-mode time drops with block density (its grid
runs over live blocks only); the dense kernels' stays flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.core import dataflow, ternary
from repro.kernels import ops
from repro.sparse import format as sparse_format, stats as sparse_stats

P_ZERO_SWEEP = (0.1, 1.0 / 3.0, 0.6, 0.9)
BK = BM = 128   # sparse block tiling for the sweep (small shapes)


def run(quick: bool = False):
    rows = []
    shapes = [(8, 512, 512)] if quick else [(8, 512, 512), (1, 1024, 1024)]
    for (n, k, m) in shapes:
        key = jax.random.PRNGKey(n + k)
        x = jax.random.normal(key, (n, k))
        scale = jnp.ones((m,))
        for p_zero in P_ZERO_SWEEP:
            # Block-structured sparsity: p_zero kills whole (BK, BM) blocks
            # (unstructured zeros never kill a full block — see
            # sparse/format.random_block_sparse_ternary).
            t = sparse_format.random_block_sparse_ternary(
                key, (k, m), bk=BK, bm=BM, p_zero_block=p_zero)
            bst = sparse_format.from_ternary(t, scale, bk=BK, bm=BM)
            density = sparse_stats.weight_density(t)
            choice = dataflow.select_kernel(
                n, k, m, density=density, block_density=bst.block_density,
                block_shape=(BK, BM))
            derived = (f"interpret_mode=1;p_zero_block={p_zero:.2f};"
                       f"density={density:.3f};block_density={bst.block_density:.3f};"
                       f"kernel_choice={choice.kernel}")

            tw = ternary.pack(t.astype(jnp.float32), scale)
            tt = timeit(lambda x: ops.tsar_matmul(x, tw, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_mxu_{n}x{k}x{m}_pz{p_zero:.2f}", tt * 1e6, derived)
            ts = timeit(lambda x: ops.tsar_sparse_matmul(x, bst, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_sparse_{n}x{k}x{m}_pz{p_zero:.2f}", ts * 1e6, derived)
            rows.append((n, k, m, p_zero, bst.block_density, ts))

        # Dense-path AP/OP + LUT baselines at the BitNet prior (unswept).
        t = ternary.random_ternary(key, (k, m))
        tw = ternary.pack(t.astype(jnp.float32), scale)
        for df in ("AP", "OP"):
            tt = timeit(lambda x: ops.tsar_matmul(x, tw, dataflow=df, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_mxu_{df}_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
        ip, iz = ternary.pack_indices(t, 4)
        tt = timeit(lambda x: ops.tsar_lut_gemv(x, ip, iz, scale, c=4, interpret=True),
                    x, reps=2, warmup=1)
        csv_row(f"pallas_lut_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
    return rows
