"""Pallas kernel timings (interpret mode — correctness-path cost only; real
TPU timings come from the roofline analysis, not this container)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.core import ternary
from repro.kernels import ops


def run(quick: bool = False):
    rows = []
    shapes = [(8, 512, 512)] if quick else [(8, 512, 512), (1, 1024, 1024)]
    for (n, k, m) in shapes:
        key = jax.random.PRNGKey(n + k)
        t = ternary.random_ternary(key, (k, m))
        scale = jnp.ones((m,))
        tw = ternary.pack(t.astype(jnp.float32), scale)
        x = jax.random.normal(key, (n, k))
        for df in ("AP", "OP"):
            tt = timeit(lambda x: ops.tsar_matmul(x, tw, dataflow=df, interpret=True),
                        x, reps=2, warmup=1)
            csv_row(f"pallas_mxu_{df}_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
        ip, iz = ternary.pack_indices(t, 4)
        tt = timeit(lambda x: ops.tsar_lut_gemv(x, ip, iz, scale, c=4, interpret=True),
                    x, reps=2, warmup=1)
        csv_row(f"pallas_lut_{n}x{k}x{m}", tt * 1e6, "interpret_mode=1")
        rows.append((n, k, m))
    return rows
