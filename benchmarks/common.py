"""Shared benchmark utilities + the BitNet model-size ladder from the paper
(Fig. 1(c)/Fig. 8 evaluate 125M -> 100B)."""
from __future__ import annotations

import time

import jax

# (name, d_model, d_ff, n_layers) — BitNet-b1.58 family dims (public configs;
# 100B extrapolated with the same aspect ratio the paper uses).
BITNET_LADDER = [
    ("125M", 768, 2048, 12),
    ("350M", 1024, 2728, 24),   # d_ff rounded to a block-size multiple
    ("1.5B", 1536, 4096, 24),
    ("2B-4T", 2560, 6912, 30),
    ("7B", 4096, 11008, 32),
    ("13B", 5120, 13824, 40),
    ("70B", 8192, 22016, 80),
    ("100B", 9216, 24576, 96),
]


def timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds per call (jitted fns; blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
