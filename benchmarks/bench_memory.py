"""Fig. 9 reproduction: kernel memory-request volume (MB), T-SAR vs TL-2.

The paper counts bytes requested from the *system memory* during one BitLinear
GEMM (N=128 prefill) / GEMV (N=1 decode) across BitNet sizes.  We reproduce
the analytic traffic model; the cache hierarchy cannot be simulated here, so
the baseline's effective bytes-per-TLUT-lookup is calibrated from the paper's
own measurements (Sec. IV-C): LLC hit rate 89% for GEMM tiles, 62% for
GEMV's random lookups, 64-byte DDR5 line granularity:

    GEMV miss traffic: (1 - 0.62) * 64 B/line ~= 24 B, but adjacent-entry
      locality within the 16-entry tables recovers ~1/3 -> ~16 B effective.
    GEMM miss traffic: (1 - 0.89) * 2 B entries (tiled, line-amortized)
      ~= 0.22 B effective per lookup.

T-SAR eliminates the lookup traffic entirely (tables live in registers/VMEM);
its weight stream is 2 b/w vs TL-2's denser 1.67 b/w — the ~20% static-size
penalty the paper's footnote concedes, visible in our model as the weights
term.  Cross-checked: the baseline TLUT share of traffic and the resulting
reduction range are compared against the paper's 87.6% / 8.7-13.8x.
"""
from __future__ import annotations

from benchmarks.common import BITNET_LADDER, csv_row

C = 4
GEMV_LOOKUP_BYTES = 12.0    # calibrated: 62% LLC hit, 64B lines, table locality
GEMM_LOOKUP_BYTES = 0.07    # calibrated: 89-91% LLC hit on tiled 2B entries


def tl2_bytes(n, k, m) -> tuple[float, float]:
    """Returns (total_bytes, tlut_bytes) for the TL-2-style baseline."""
    blocks = k / C
    weights = k * m * 1.67 / 8
    lookup_eff = GEMM_LOOKUP_BYTES if n > 1 else GEMV_LOOKUP_BYTES
    lut_store = n * blocks * (3 ** C) * 2          # table writes (16-bit entries)
    lut_fetch = n * blocks * m * lookup_eff        # the Fig. 2(c) dominant term
    acts = n * k
    outs = n * m * 4
    return weights + lut_store + lut_fetch + acts + outs, lut_store + lut_fetch


def tsar_bytes(n, k, m) -> float:
    weights = k * m * 2 / 8                        # 1+1-bit planes, no TLUT traffic
    acts = n * k
    outs = n * m * 4
    return weights + acts + outs


def _block_shapes(d, f):
    return [(d, 3 * d), (d, f), (f, d)]


def run(quick: bool = False):
    rows = []
    tlut_shares = []
    for name, d, f, nl in BITNET_LADDER:
        for kind, n in (("gemm_prefill", 128), ("gemv_decode", 1)):
            tl2 = [tl2_bytes(n, k, m) for k, m in _block_shapes(d, f)]
            t_tl2 = sum(t for t, _ in tl2) * nl / 1e6
            t_lut = sum(l for _, l in tl2) * nl / 1e6
            t_tsar = sum(tsar_bytes(n, k, m) for k, m in _block_shapes(d, f)) * nl / 1e6
            red = t_tl2 / t_tsar
            if kind == "gemv_decode":
                tlut_shares.append(t_lut / t_tl2)
            csv_row(f"mem_{kind}_{name}", 0.0,
                    f"tl2_MB={t_tl2:.1f};tsar_MB={t_tsar:.1f};reduction={red:.1f}x")
            rows.append({"size": name, "kind": kind, "tl2_mb": t_tl2,
                         "tsar_mb": t_tsar, "reduction": red})
    # Block-sparse format static footprint vs dense 2-bit planes: pool bytes
    # scale with live blocks; the index map + occupancy metadata are the
    # overhead that makes the format a net loss near 100% live blocks.
    bk = bm = 256
    for name, d, f, nl in BITNET_LADDER[:1] + BITNET_LADDER[3:4]:
        dense_b = sum(k * m * 2 / 8 for k, m in _block_shapes(d, f)) * nl
        for live in (1.0, 0.9, 0.5, 0.1):
            sparse_b = 0.0
            for k, m in _block_shapes(d, f):
                kb, mb = -(-k // bk), -(-m // bm)
                sparse_b += (live * kb * mb * (bk // 8) * bm * 2   # pools
                             + kb * mb * 8)                        # map+occupancy
            sparse_b *= nl
            csv_row(f"mem_sparse_footprint_{name}_live{live:.1f}", 0.0,
                    f"dense2bit_MB={dense_b/1e6:.1f};sparse_MB={sparse_b/1e6:.1f};"
                    f"ratio={sparse_b/dense_b:.2f}")
            rows.append({"size": name, "kind": f"sparse_footprint_{live:.1f}",
                         "tl2_mb": dense_b / 1e6, "tsar_mb": sparse_b / 1e6,
                         "reduction": dense_b / max(sparse_b, 1e-9)})
    gemv = [r["reduction"] for r in rows if r["kind"] == "gemv_decode"]
    gemm = [r["reduction"] for r in rows if r["kind"] == "gemm_prefill"]
    csv_row("mem_reduction_range", 0.0,
            f"gemv={min(gemv):.1f}-{max(gemv):.1f}x;gemm={min(gemm):.1f}-{max(gemm):.1f}x;"
            f"paper=8.7-13.8x")
    csv_row("mem_tlut_share_of_baseline", 0.0,
            f"model={100*sum(tlut_shares)/len(tlut_shares):.1f}%;paper=87.6%")
    return rows


if __name__ == "__main__":
    run()
