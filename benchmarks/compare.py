"""Regression gate over persisted ``BENCH_e2e.json`` reports.

``python -m benchmarks.compare RUN BASELINE [options]`` diffs a fresh run
against a committed baseline and exits nonzero on regression, so CI can gate
on the serving perf trajectory (see the ``bench`` lane in
``.github/workflows/ci.yml`` and docs/benchmarking.md for the
baseline-update workflow).

Metrics are gated by class, not uniformly:

* **deterministic counters** (preemptions, scheduled prefill tokens, cache
  hit rates, step counts, plan kernel) are a pure function of (trace, code)
  — compared EXACTLY by default (``--counter-tol`` relaxes to a relative
  tolerance).  A counter drift means scheduling behavior changed, which is
  either an intended change (update the baseline) or a real bug — never
  machine noise.  The metrics-registry snapshot rides this section too:
  ``engine_counters`` folds the registry's step-accounting counters
  (planned/realized tokens, prefill/decode step split, admissions) into
  every block, so the exact gate covers them the moment they appear in the
  committed baseline — no comparator change needed for new counters.
  (Observability-trace provenance, by contrast, lives at the block level
  as ``obs_trace`` and is deliberately NOT gated: attaching a tracer must
  never perturb the exact-gated numbers.)
* **timing metrics** (TTFT/TPOT/queue percentiles, wall time, token rates)
  are wall-clock — gated by a relative tolerance (``--timing-tol``,
  default 0.15: flag anything >15% worse) with an absolute floor
  (``--timing-floor``) so micro-jitter on sub-millisecond values doesn't
  flake.  CI passes a looser tolerance than the default, since its machines
  differ from whoever cut the baseline.
* **goodput** (``slo_attained``) is gated by absolute drop
  (``--goodput-tol``, default 0.1).

Traces must match: a run whose ``trace_fingerprint`` differs from the
baseline's is measuring a different workload, and its numbers are not
comparable — that's an error unless ``--allow-trace-drift`` is passed
(which skips the drifted workload with a note, for intentional workload
redesigns).
"""
from __future__ import annotations

import argparse
import math
import sys

from benchmarks.workloads import schema

_PCT_KEYS = ("p50", "p90", "p99", "mean", "max")
_LATENCY_BLOCKS = ("ttft_s", "tpot_s", "queue_s")


def _worse_higher(run: float, base: float, tol: float, floor: float) -> bool:
    """Higher-is-worse timing check with relative tolerance + abs floor."""
    if math.isnan(run) or math.isnan(base):
        return math.isnan(run) != math.isnan(base)
    return run > base * (1.0 + tol) and (run - base) > floor


def _worse_lower(run: float, base: float, tol: float) -> bool:
    """Lower-is-worse (rates): flag when run < base by more than tol."""
    if math.isnan(run) or math.isnan(base):
        return math.isnan(run) != math.isnan(base)
    return run < base * (1.0 - tol)


def compare(run: dict, base: dict, *, timing_tol: float = 0.15,
            timing_floor: float = 0.002, counter_tol: float = 0.0,
            goodput_tol: float = 0.1,
            allow_trace_drift: bool = False) -> list[str]:
    """Returns a list of regression descriptions (empty = pass)."""
    regs: list[str] = []
    for doc, label in ((run, "run"), (base, "baseline")):
        schema.validate(doc)
    if run["schema_version"] != base["schema_version"]:
        return [f"schema_version {run['schema_version']} != "
                f"baseline {base['schema_version']} (not comparable)"]
    if run["quick"] != base["quick"]:
        return [f"quick={run['quick']} vs baseline quick={base['quick']} "
                "(different suite sizes are not comparable)"]

    for name, b in base["workloads"].items():
        r = run["workloads"].get(name)
        if r is None:
            regs.append(f"{name}: workload missing from run "
                        "(baseline still expects it)")
            continue
        if r["trace_fingerprint"] != b["trace_fingerprint"]:
            msg = (f"{name}: trace fingerprint drifted "
                   f"({r['trace_fingerprint'][:18]}… != "
                   f"{b['trace_fingerprint'][:18]}…)")
            if allow_trace_drift:
                print(f"note: {msg} — skipped", file=sys.stderr)
                continue
            regs.append(msg + " — numbers not comparable "
                        "(--allow-trace-drift to skip)")
            continue

        rm, bm = r["metrics"], b["metrics"]
        for blk in _LATENCY_BLOCKS:
            for k in _PCT_KEYS:
                rv, bv = rm[blk][k], bm[blk][k]
                if _worse_higher(rv, bv, timing_tol, timing_floor):
                    regs.append(
                        f"{name}: {blk}.{k} regressed "
                        f"{bv * 1e3:.2f}ms -> {rv * 1e3:.2f}ms "
                        f"(+{(rv / bv - 1) * 100:.0f}% > "
                        f"{timing_tol * 100:.0f}%)")
            if rm[blk]["n"] < bm[blk]["n"]:
                regs.append(f"{name}: {blk}.n fell "
                            f"{bm[blk]['n']} -> {rm[blk]['n']} "
                            "(fewer measured requests)")
        rg, bg = rm["goodput"], bm["goodput"]
        if not math.isnan(bg["slo_attained"]):
            if rg["slo_attained"] < bg["slo_attained"] - goodput_tol:
                regs.append(
                    f"{name}: goodput fell {bg['slo_attained']:.2f} -> "
                    f"{rg['slo_attained']:.2f} (drop > {goodput_tol})")
        if _worse_lower(rm["output_tok_s"], bm["output_tok_s"], timing_tol):
            regs.append(f"{name}: output_tok_s fell "
                        f"{bm['output_tok_s']:.1f} -> "
                        f"{rm['output_tok_s']:.1f}")

        rc, bc = r["counters"], b["counters"]
        for k, bv in bc.items():
            if k not in rc:
                regs.append(f"{name}: counter {k} missing from run")
                continue
            rv = rc[k]
            if isinstance(bv, str):
                if rv != bv:
                    regs.append(f"{name}: counter {k} changed "
                                f"{bv!r} -> {rv!r}")
            elif counter_tol > 0:
                lo = min(bv * (1 - counter_tol), bv - 1e-12)
                hi = max(bv * (1 + counter_tol), bv + 1e-12)
                if not (lo <= rv <= hi):
                    regs.append(f"{name}: counter {k} drifted {bv} -> {rv} "
                                f"(> {counter_tol * 100:.0f}%)")
            elif rv != bv:
                regs.append(f"{name}: counter {k} changed {bv} -> {rv} "
                            "(deterministic counters gate exactly; "
                            "intended? update the baseline)")
    return regs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a BENCH_e2e.json run against a baseline; "
                    "exit 1 on regression.")
    ap.add_argument("run", help="fresh BENCH_e2e.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--timing-tol", type=float, default=0.15,
                    help="relative tolerance for wall-clock metrics "
                         "(default 0.15)")
    ap.add_argument("--timing-floor", type=float, default=0.002,
                    help="absolute regression floor in seconds "
                         "(default 2ms)")
    ap.add_argument("--counter-tol", type=float, default=0.0,
                    help="relative tolerance for deterministic counters "
                         "(default 0 = exact)")
    ap.add_argument("--goodput-tol", type=float, default=0.1,
                    help="max allowed absolute goodput drop (default 0.1)")
    ap.add_argument("--allow-trace-drift", action="store_true",
                    help="skip (don't fail) workloads whose trace "
                         "fingerprint changed")
    args = ap.parse_args(argv)

    try:
        run = schema.load(args.run)
        base = schema.load(args.baseline)
    except (OSError, ValueError) as e:
        print(f"compare: cannot load reports: {e}", file=sys.stderr)
        return 2
    regs = compare(run, base, timing_tol=args.timing_tol,
                   timing_floor=args.timing_floor,
                   counter_tol=args.counter_tol,
                   goodput_tol=args.goodput_tol,
                   allow_trace_drift=args.allow_trace_drift)
    if regs:
        print(f"REGRESSIONS ({len(regs)}):")
        for r in regs:
            print(f"  - {r}")
        return 1
    nw = len(base["workloads"])
    print(f"compare: OK — {nw} baseline workloads within tolerance "
          f"(run rev {run['git_rev'][:12]}, "
          f"baseline rev {base['git_rev'][:12]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
