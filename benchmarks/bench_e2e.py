"""Fig. 8 reproduction: end-to-end prefill latency + decode throughput,
T-SAR vs memory-LUT baseline vs dense-fp, on the BitLinear kernel level —
plus a serving-level section reporting TTFT / TPOT / tokens-per-second for
the chunked-prefill engine under mixed prompt lengths (``run_serving``).

The paper measures gem5-simulated CPUs; our measured substrate is the jitted
algorithm on this container's CPU — the *relative* speedups (T-SAR over the
DRAM-LUT baseline) are the reproduced quantity, per-model-size, with the
paper's protocol (prefill N=128 batch=1; decode steady-state, Sec. IV-A).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import BITNET_LADDER, csv_row, timeit
from repro.core import dataflow, lut, ternary

C = 4
PREFILL_N = 128  # paper protocol


def _layer_mats(key, d, f):
    """One transformer block's BitLinear shapes: qkvo fused + mlp up/down."""
    k1, k2, k3 = jax.random.split(key, 3)
    return [
        ternary.random_ternary(k1, (d, 3 * d)),     # qkv (fused)
        ternary.random_ternary(k2, (d, f)),         # up
        ternary.random_ternary(k3, (f, d)),         # down
    ]


def _build_fns(mats, mode, n):
    """Chain the block's matmuls as one jitted fn of (activations, weights).

    'tsar' uses the compile-time kernel selector per layer shape (paper
    Sec. III-D): the in-VMEM LUT spelling or the decode-to-MXU spelling,
    whichever the cost model picks for this (n, k, m).

    Weight encodings are passed as jit ARGUMENTS (not closure constants) —
    XLA constant-folds gathers over constant tables, which both distorts the
    baseline and stalls compilation for minutes.
    """
    kinds, args = [], []
    for t in mats:
        k_, m_ = t.shape
        if mode == "tsar":
            # On this backend the decode-near-datapath spelling (int8 dot) is
            # always the right realization — CPU/TPU gathers are not the SIMD
            # in-register gathers the cost model's tsar_lut estimate assumes.
            # The in-VMEM LUT spelling is measured separately (bench_scaling).
            kinds.append("tsar_mxu")
            args.append((t, jnp.ones((m_,))))
        elif mode == "memory_lut":
            kinds.append("mem")
            args.append(lut.ternary_lut_indices(t, C))
        else:
            kinds.append("dense")
            args.append(t.astype(jnp.float32))

    kdims = [t.shape[0] for t in mats]

    def adapt(x, k_need):
        if x.shape[-1] == k_need:
            return x
        if x.shape[-1] > k_need:
            return x[..., :k_need]
        return jnp.pad(x, ((0, 0), (0, k_need - x.shape[-1])))

    def fwd(a, enc):
        x = a
        for kind, e, k_need in zip(kinds, enc, kdims):
            x = adapt(x, k_need)
            if kind == "tsar_lut":
                ip, iz = e
                x = lut.tsar_lut_matmul(x, ip, iz, C)
            elif kind == "tsar_mxu":
                t_, sc = e
                x = lut.bitlinear_matmul_fast(x, t_, sc)
            elif kind == "mem":
                x = lut.memory_lut_matmul(x, e, C)
            else:
                x = x @ e
        return x

    return jax.jit(fwd), args


def run(sizes=("125M", "2B-4T", "7B"), quick: bool = False):
    rows = []
    for name, d, f, nl in BITNET_LADDER:
        if name not in sizes:
            continue
        key = jax.random.PRNGKey(hash(name) % 2**31)
        mats = _layer_mats(key, d, f)
        a_prefill = jax.random.normal(key, (PREFILL_N, d))
        a_decode = jax.random.normal(key, (1, d))

        res = {}
        for mode in ("tsar", "memory_lut", "dense"):
            fn_p, enc_p = _build_fns(mats, mode, PREFILL_N)
            res[(mode, "prefill")] = timeit(fn_p, a_prefill, enc_p,
                                            reps=2 if quick else 3)
            fn_d, enc_d = _build_fns(mats, mode, 1)
            res[(mode, "decode")] = timeit(fn_d, a_decode, enc_d,
                                           reps=2 if quick else 3)

        sp_pre = res[("memory_lut", "prefill")] / res[("tsar", "prefill")]
        sp_dec = res[("memory_lut", "decode")] / res[("tsar", "decode")]
        dn_pre = res[("dense", "prefill")] / res[("tsar", "prefill")]
        dn_dec = res[("dense", "decode")] / res[("tsar", "decode")]
        csv_row(f"e2e_prefill_{name}_tsar", res[("tsar", "prefill")] * 1e6,
                f"speedup_vs_memlut={sp_pre:.2f}x;vs_dense={dn_pre:.2f}x")
        csv_row(f"e2e_decode_{name}_tsar", res[("tsar", "decode")] * 1e6,
                f"speedup_vs_memlut={sp_dec:.2f}x;vs_dense={dn_dec:.2f}x;"
                f"decode_tok_s={1.0/res[('tsar','decode')]:.1f}")
        rows.append({"size": name, "prefill_speedup": sp_pre, "decode_speedup": sp_dec,
                     "times": {f"{m}_{p}": v for (m, p), v in res.items()}})
    return rows


def run_serving(arch: str = "bitnet-2b-4t", quick: bool = False,
                workload: str = "mixed"):
    """Serving-level latency under mixed prompt lengths: TTFT (admission +
    prefill), TPOT (decode cadence) and steady-state tokens/s, chunked
    prefill vs whole-prompt prefill, qat vs packed 2-bit weights.

    The chunked engine's defining property shows up in ``max_step_tokens``:
    bounded by prefill_chunk + slots, where the whole-prompt policy spikes to
    the longest prompt length.

    ``workload="shared-prefix"`` instead measures prefix-caching KV reuse:
    N requests share a system prompt (~75% of each prompt), served with the
    prefix cache off and on.  Rows/CSV carry ``prefix_hit_rate`` and the
    TTFT columns, so the TTFT-vs-hit-rate relation is one CSV away; the
    scenario doubles as the serving regression lane's smoke — it ASSERTS
    cache-on outputs token-identical to cache-off.
    """
    if workload == "shared-prefix":
        return _run_serving_shared_prefix(arch, quick)
    if workload != "mixed":
        raise ValueError(f"unknown serving workload {workload!r}")
    import repro.configs as configs
    from repro.models import model_zoo as zoo
    from repro.serving import Request, ServingEngine

    chunk, slots, max_new = 16, 4, 8 if quick else 16
    cfg = configs.get(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [5, 9, 3 * chunk, 12, 6 * chunk, 7, 24, 4 * chunk]
    mk = lambda: [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=s),
                          max_new_tokens=max_new)
                  for i, s in enumerate(lens[: 4 if quick else len(lens)])]

    rows = []
    for policy in ("chunked", "whole"):
        for packed in ((False, True) if not quick else (True,)):
            eng = ServingEngine(cfg, params, max_len=256, batch_slots=slots,
                                packed=packed, prefill_chunk=chunk,
                                policy=policy)
            reqs = eng.run(mk())
            lat = eng.latency_stats(reqs)
            # The decode-bucket kernel the compiled execution plan committed
            # to (qat engines carry no plan): the CI smoke step asserts this
            # column exists so the plan path can't silently fall out of the
            # serving benchmark.  Pure-decode steps run (slots, 1) tokens, so
            # the bucket the serving loop actually dispatches is n=slots.
            plan_kernel = (eng.plan.dominant_kernel(slots)
                           if eng.plan is not None else "none")
            name = f"serve_{arch}_{policy}_{'packed' if packed else 'qat'}"
            csv_row(name, lat["ttft_mean_s"] * 1e6,
                    f"ttft_max_ms={lat['ttft_max_s'] * 1e3:.1f};"
                    f"tpot_ms={lat['tpot_mean_s'] * 1e3:.2f};"
                    f"decode_tok_s={eng.throughput():.1f};"
                    f"max_step_tokens={eng.max_step_tokens()};"
                    f"peak_kv_blocks={eng.stats['peak_kv_blocks']};"
                    f"plan_kernel={plan_kernel}")
            rows.append({"policy": policy, "packed": packed, **lat,
                         "plan_kernel": plan_kernel,
                         "decode_tok_s": eng.throughput(),
                         "max_step_tokens": eng.max_step_tokens()})
    return rows


def _run_serving_shared_prefix(arch: str, quick: bool = False):
    """N requests sharing a system prompt, prefix cache off vs on."""
    import repro.configs as configs
    from repro.models import model_zoo as zoo
    from repro.serving import Request, ServingEngine

    chunk, slots, max_new = 16, 2, 8
    n_req = 4 if quick else 6
    sys_len, tail_len = 48, 16                      # 75%-shared prompts
    cfg = configs.get(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=sys_len)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, cfg.vocab_size, size=tail_len)])
               for _ in range(n_req)]
    mk = lambda: [Request(uid=i, prompt=prompts[i], max_new_tokens=max_new)
                  for i in range(n_req)]

    rows, outs = [], {}
    for prefix_cache in (False, True):
        eng = ServingEngine(cfg, params, max_len=256, batch_slots=slots,
                            packed=True, prefill_chunk=chunk,
                            policy="chunked", prefix_cache=prefix_cache)
        reqs = eng.run(mk())
        lat = eng.latency_stats(reqs)
        outs[prefix_cache] = [r.out_tokens for r in reqs]
        hit_rate = eng.stats.get("prefix_hit_rate", 0.0)
        plan_kernel = (eng.plan.dominant_kernel(slots)
                       if eng.plan is not None else "none")
        label = "cache" if prefix_cache else "nocache"
        csv_row(f"serve_{arch}_sharedprefix_{label}",
                lat["ttft_mean_s"] * 1e6,
                f"ttft_max_ms={lat['ttft_max_s'] * 1e3:.1f};"
                f"tpot_ms={lat['tpot_mean_s'] * 1e3:.2f};"
                f"prefix_hit_rate={hit_rate:.3f};"
                f"cached_blocks={eng.stats.get('cached_blocks', 0)};"
                f"prefill_tokens={eng.stats['prefill_tokens']};"
                f"plan_kernel={plan_kernel}")
        rows.append({"workload": "shared-prefix", "prefix_cache": prefix_cache,
                     "prefix_hit_rate": hit_rate,
                     "cached_blocks": eng.stats.get("cached_blocks", 0),
                     "prefill_tokens": eng.stats["prefill_tokens"],
                     "plan_kernel": plan_kernel,
                     "decode_tok_s": eng.throughput(), **lat})
    # Serving regression contract: the hit path must be token-identical to
    # the cold path on the same requests.
    assert outs[True] == outs[False], \
        "prefix-cache hit path diverged from cold path"
    return rows


if __name__ == "__main__":
    run()
    run_serving()
    run_serving(workload="shared-prefix")
