"""Fig. 8 reproduction: end-to-end prefill latency + decode throughput,
T-SAR vs memory-LUT baseline vs dense-fp, on the BitLinear kernel level —
plus a serving-level section reporting TTFT / TPOT / tokens-per-second for
the chunked-prefill engine under mixed prompt lengths (``run_serving``).

The paper measures gem5-simulated CPUs; our measured substrate is the jitted
algorithm on this container's CPU — the *relative* speedups (T-SAR over the
DRAM-LUT baseline) are the reproduced quantity, per-model-size, with the
paper's protocol (prefill N=128 batch=1; decode steady-state, Sec. IV-A).
"""
from __future__ import annotations

import sys

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import BITNET_LADDER, csv_row, timeit
from repro.core import dataflow, lut, ternary

C = 4
PREFILL_N = 128  # paper protocol


def _layer_mats(key, d, f):
    """One transformer block's BitLinear shapes: qkvo fused + mlp up/down."""
    k1, k2, k3 = jax.random.split(key, 3)
    return [
        ternary.random_ternary(k1, (d, 3 * d)),     # qkv (fused)
        ternary.random_ternary(k2, (d, f)),         # up
        ternary.random_ternary(k3, (f, d)),         # down
    ]


def _build_fns(mats, mode, n):
    """Chain the block's matmuls as one jitted fn of (activations, weights).

    'tsar' uses the compile-time kernel selector per layer shape (paper
    Sec. III-D): the in-VMEM LUT spelling or the decode-to-MXU spelling,
    whichever the cost model picks for this (n, k, m).

    Weight encodings are passed as jit ARGUMENTS (not closure constants) —
    XLA constant-folds gathers over constant tables, which both distorts the
    baseline and stalls compilation for minutes.
    """
    kinds, args = [], []
    for t in mats:
        k_, m_ = t.shape
        if mode == "tsar":
            # On this backend the decode-near-datapath spelling (int8 dot) is
            # always the right realization — CPU/TPU gathers are not the SIMD
            # in-register gathers the cost model's tsar_lut estimate assumes.
            # The in-VMEM LUT spelling is measured separately (bench_scaling).
            kinds.append("tsar_mxu")
            args.append((t, jnp.ones((m_,))))
        elif mode == "memory_lut":
            kinds.append("mem")
            args.append(lut.ternary_lut_indices(t, C))
        else:
            kinds.append("dense")
            args.append(t.astype(jnp.float32))

    kdims = [t.shape[0] for t in mats]

    def adapt(x, k_need):
        if x.shape[-1] == k_need:
            return x
        if x.shape[-1] > k_need:
            return x[..., :k_need]
        return jnp.pad(x, ((0, 0), (0, k_need - x.shape[-1])))

    def fwd(a, enc):
        x = a
        for kind, e, k_need in zip(kinds, enc, kdims):
            x = adapt(x, k_need)
            if kind == "tsar_lut":
                ip, iz = e
                x = lut.tsar_lut_matmul(x, ip, iz, C)
            elif kind == "tsar_mxu":
                t_, sc = e
                x = lut.bitlinear_matmul_fast(x, t_, sc)
            elif kind == "mem":
                x = lut.memory_lut_matmul(x, e, C)
            else:
                x = x @ e
        return x

    return jax.jit(fwd), args


def run(sizes=("125M", "2B-4T", "7B"), quick: bool = False):
    rows = []
    for name, d, f, nl in BITNET_LADDER:
        if name not in sizes:
            continue
        key = jax.random.PRNGKey(hash(name) % 2**31)
        mats = _layer_mats(key, d, f)
        a_prefill = jax.random.normal(key, (PREFILL_N, d))
        a_decode = jax.random.normal(key, (1, d))

        res = {}
        for mode in ("tsar", "memory_lut", "dense"):
            fn_p, enc_p = _build_fns(mats, mode, PREFILL_N)
            res[(mode, "prefill")] = timeit(fn_p, a_prefill, enc_p,
                                            reps=2 if quick else 3)
            fn_d, enc_d = _build_fns(mats, mode, 1)
            res[(mode, "decode")] = timeit(fn_d, a_decode, enc_d,
                                           reps=2 if quick else 3)

        sp_pre = res[("memory_lut", "prefill")] / res[("tsar", "prefill")]
        sp_dec = res[("memory_lut", "decode")] / res[("tsar", "decode")]
        dn_pre = res[("dense", "prefill")] / res[("tsar", "prefill")]
        dn_dec = res[("dense", "decode")] / res[("tsar", "decode")]
        csv_row(f"e2e_prefill_{name}_tsar", res[("tsar", "prefill")] * 1e6,
                f"speedup_vs_memlut={sp_pre:.2f}x;vs_dense={dn_pre:.2f}x")
        csv_row(f"e2e_decode_{name}_tsar", res[("tsar", "decode")] * 1e6,
                f"speedup_vs_memlut={sp_dec:.2f}x;vs_dense={dn_dec:.2f}x;"
                f"decode_tok_s={1.0/res[('tsar','decode')]:.1f}")
        rows.append({"size": name, "prefill_speedup": sp_pre, "decode_speedup": sp_dec,
                     "times": {f"{m}_{p}": v for (m, p), v in res.items()}})
    return rows


def _load_model(arch: str):
    import repro.configs as configs
    from repro.models import model_zoo as zoo

    cfg = configs.get(arch).reduced()
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


def run_serving(arch: str = "bitnet-2b-4t", quick: bool = False,
                workload: str = "mixed", trace_out: str | None = None):
    """Serving-level latency, now trace-driven: the request list is a seeded
    :class:`benchmarks.workloads.Trace` (``preset(workload)``) replayed in
    virtual time, so the scheduling structure is reproducible from the trace
    alone and the percentile TTFT/TPOT columns come from the shared metrics
    layer.

    ``workload="mixed"`` keeps the historical comparison: flat token-packed
    vs chunked vs whole-prompt prefill, qat vs packed 2-bit weights, over
    the same mixed prompt-length burst.  The flat/chunked engines' defining
    property shows up in ``max_step_tokens``: bounded by
    token_budget == prefill_chunk + slots, where the whole-prompt policy
    spikes to the longest prompt length.

    ``workload="shared-prefix"`` measures prefix-caching KV reuse: the trace
    shares system prompts across groups, replayed with the cache off and on.
    Rows/CSV carry ``prefix_hit_rate`` next to the TTFT columns, and the
    scenario ASSERTS cache-on outputs token-identical to cache-off (the
    serving-regression contract).

    ``trace_out`` (shared-prefix only) saves the warm replay's
    observability trace as Perfetto ``trace_event`` JSON — inspect with
    ``python -m repro.obs.timeline`` or load in chrome://tracing.
    """
    if workload == "shared-prefix":
        return _run_serving_shared_prefix(arch, quick, trace_out=trace_out)
    if workload != "mixed":
        raise ValueError(f"unknown serving workload {workload!r}")
    if trace_out is not None:
        raise ValueError("trace_out is only wired for the shared-prefix "
                         "serving workload")
    from benchmarks.workloads import generator, runner

    cfg, params = _load_model(arch)
    spec = generator.preset("mixed", quick=quick)
    trace = generator.generate(spec)

    rows = []
    for policy in ("flat", "chunked", "whole"):
        for packed in ((False, True) if not quick else (True,)):
            block, eng, reqs = runner.run_workload(
                spec, cfg, params, packed=packed, policy=policy, trace=trace)
            m, c = block["metrics"], block["counters"]
            name = f"serve_{arch}_{policy}_{'packed' if packed else 'qat'}"
            csv_row(name, m["ttft_s"]["p50"] * 1e6,
                    f"ttft_p99_ms={m['ttft_s']['p99'] * 1e3:.1f};"
                    f"tpot_p50_ms={m['tpot_s']['p50'] * 1e3:.2f};"
                    f"out_tok_s={m['output_tok_s']:.1f};"
                    f"max_step_tokens={c['max_step_tokens']};"
                    f"peak_kv_blocks={c['peak_kv_blocks']};"
                    f"plan_kernel={c['plan_kernel']}")
            rows.append({"policy": policy, "packed": packed,
                         "trace_fingerprint": block["trace_fingerprint"],
                         "plan_kernel": c["plan_kernel"],
                         "decode_tok_s": m["output_tok_s"],
                         "ttft_p50_s": m["ttft_s"]["p50"],
                         "ttft_p99_s": m["ttft_s"]["p99"],
                         "tpot_p50_s": m["tpot_s"]["p50"],
                         "max_step_tokens": c["max_step_tokens"],
                         "prefill_tokens": c["prefill_tokens"]})
    return rows


def _run_serving_shared_prefix(arch: str, quick: bool = False,
                               trace_out: str | None = None):
    """The shared-prefix trace, prefix cache off vs on (same trace)."""
    from benchmarks.workloads import generator, runner

    cfg, params = _load_model(arch)
    spec = generator.preset("shared-prefix", quick=quick)
    trace = generator.generate(spec)

    rows, outs = [], {}
    for prefix_cache in (False, True):
        tracer = None
        if trace_out is not None and prefix_cache:
            from repro.obs.trace import EventTracer
            tracer = EventTracer()
        block, eng, reqs = runner.run_workload(
            spec, cfg, params, trace=trace, prefix_cache=prefix_cache,
            tracer=tracer)
        if tracer is not None:
            doc = tracer.save(trace_out)
            print(f"# obs trace: {trace_out} "
                  f"({len(doc['traceEvents'])} events)", file=sys.stderr)
        m, c = block["metrics"], block["counters"]
        outs[prefix_cache] = [r.out_tokens for r in reqs]
        hit_rate = c.get("prefix_hit_rate", 0.0)
        label = "cache" if prefix_cache else "nocache"
        csv_row(f"serve_{arch}_sharedprefix_{label}",
                m["ttft_s"]["p50"] * 1e6,
                f"ttft_p99_ms={m['ttft_s']['p99'] * 1e3:.1f};"
                f"tpot_p50_ms={m['tpot_s']['p50'] * 1e3:.2f};"
                f"prefix_hit_rate={hit_rate:.3f};"
                f"cached_blocks={c.get('cached_blocks', 0)};"
                f"prefill_tokens={c['prefill_tokens']};"
                f"plan_kernel={c['plan_kernel']}")
        rows.append({"workload": "shared-prefix", "prefix_cache": prefix_cache,
                     "trace_fingerprint": block["trace_fingerprint"],
                     "prefix_hit_rate": hit_rate,
                     "cached_blocks": c.get("cached_blocks", 0),
                     "prefill_tokens": c["prefill_tokens"],
                     "plan_kernel": c["plan_kernel"],
                     "ttft_p50_s": m["ttft_s"]["p50"],
                     "tpot_p50_s": m["tpot_s"]["p50"],
                     "decode_tok_s": m["output_tok_s"]})
    # Serving regression contract: the hit path must be token-identical to
    # the cold path on the same requests.
    assert outs[True] == outs[False], \
        "prefix-cache hit path diverged from cold path"
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="Fig. 8 end-to-end bench + serving TTFT/TPOT scenarios.")
    ap.add_argument("--quick", action="store_true", help="fewer reps/sizes")
    ap.add_argument("--arch", default="bitnet-2b-4t",
                    help="serving model config (default: %(default)s)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="save the shared-prefix warm replay's "
                         "observability trace (Perfetto trace_event JSON)")
    args = ap.parse_args()
    run(sizes=("125M", "2B-4T") if args.quick else ("125M", "2B-4T", "7B"),
        quick=args.quick)
    run_serving(arch=args.arch, quick=args.quick)
    run_serving(arch=args.arch, quick=args.quick, workload="shared-prefix",
                trace_out=args.trace_out)
