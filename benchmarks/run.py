"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_e2e      — Fig. 8  end-to-end prefill/decode, T-SAR vs baselines,
                   + serving TTFT/TPOT (chunked-prefill engine, mixed prompts
                   and the shared-prefix prefix-cache scenario)
  bench_memory   — Fig. 9  memory-request volume model (validated vs dry-run)
  bench_scaling  — Fig. 10 kernel microbench (paper shapes) + chip scaling
  bench_energy   — Table III decode throughput + energy/token
  bench_kernels  — Pallas kernel interpret-mode timings (small shapes)

The ``serving`` suite additionally runs the trace-driven workload harness
(``benchmarks.workloads``) over the full preset taxonomy — steady / bursty /
shared-prefix / decode-heavy / preemption-storm / eviction-pressure — and
persists the schema-validated percentile + goodput + counter report to
``--out`` (default ``BENCH_e2e.json``).  ``benchmarks/compare.py`` diffs
that report against a committed baseline for CI regression gating; see
docs/benchmarking.md.

``python -m benchmarks.run [--quick] [--only SUITE] [--out PATH] [--seed N]``
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer reps/sizes")
    ap.add_argument("--only", default=None,
                    choices=[None, "e2e", "memory", "scaling", "energy", "kernels",
                             "serving"])
    ap.add_argument("--out", default="BENCH_e2e.json", metavar="PATH",
                    help="where the serving suite writes its BENCH_e2e "
                         "report (default: %(default)s)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload-generation seed for the serving suite "
                         "(part of every trace's identity)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="serving suite: save the shared-prefix warm "
                         "replay's observability trace (Perfetto "
                         "trace_event JSON; analyze with "
                         "python -m repro.obs.timeline PATH) — a JSONL "
                         "stream of the same run goes to PATH's .jsonl "
                         "sibling with fingerprint identity asserted")
    ap.add_argument("--incident-dir", default=None, metavar="DIR",
                    help="serving suite: arm per-workload incident "
                         "snapshots (SLO breach/preemption/rejection/"
                         "kv-pressure/eviction-storm) into DIR")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks import bench_e2e, bench_energy, bench_kernels, bench_memory, bench_scaling

    def check_serving(rows):
        # Smoke-level contract: serving rows must carry the execution plan's
        # kernel choice, so a regression that drops the plan path out of the
        # engine fails CI loudly instead of rotting silently.
        assert rows, "run_serving produced no rows"
        missing = [r for r in rows if "plan_kernel" not in r]
        assert not missing, f"serving rows missing plan_kernel: {missing}"
        # Prefix-cache contract: the shared-prefix workload must actually
        # hit (a zero hit rate means lookup/registration rotted), and the
        # scenario itself asserts cache-on == cache-off token identity.
        shared = [r for r in rows if r.get("workload") == "shared-prefix"]
        assert shared, "shared-prefix serving workload missing"
        warm = [r for r in shared if r.get("prefix_cache")]
        assert warm and all(r["prefix_hit_rate"] > 0 for r in warm), \
            f"prefix cache never hit: {warm}"
        cold = [r for r in shared if not r.get("prefix_cache")]
        assert all(w["prefill_tokens"] < c["prefill_tokens"]
                   for w in warm for c in cold), \
            "prefix cache did not reduce scheduled prefill tokens"
        return rows

    def serving():
        # Policy/weight-format comparison rows (mixed + shared-prefix traces)
        # feed the CSV; the workload suite then runs the full preset taxonomy
        # and persists the regression-gated BENCH_e2e report.
        from benchmarks.workloads import runner, schema

        check_serving(
            bench_e2e.run_serving(quick=args.quick)
            + bench_e2e.run_serving(quick=args.quick,
                                    workload="shared-prefix"))
        report = runner.run_suite(quick=args.quick, seed=args.seed,
                                  trace_out=args.trace_out,
                                  incident_dir=args.incident_dir)
        schema.save(report, args.out)
        print(f"# serving report: {args.out} "
              f"({len(report['workloads'])} workloads, seed {args.seed})",
              file=sys.stderr)

    suites = {
        "memory": lambda: bench_memory.run(quick=args.quick),
        # 7B+ excluded by default: the memory-LUT *baseline* needs ~6 GB/gather
        # buffer at N=128 on this 35 GB container (T-SAR itself is fine).
        "e2e": lambda: bench_e2e.run(
            sizes=("125M", "2B-4T") if args.quick else ("125M", "350M", "1.5B", "2B-4T"),
            quick=args.quick),
        "scaling": lambda: bench_scaling.run(quick=args.quick),
        "energy": lambda: bench_energy.run(quick=args.quick),
        "kernels": lambda: bench_kernels.run(quick=args.quick),
        "serving": serving,
    }
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn()


if __name__ == "__main__":
    main()
